"""Figs. 6-7: equality-query cost per column — wall-clock of our codec AND
the machine-independent proxy (compressed words scanned), sorted vs
unsorted, k = 1, 2.  The paper's (2 - 1/k) * n_i^((k-1)/k) model is checked
on the words-scanned proxy.

Queries run through the predicate planner (repro.core.query) on both
execution backends: ``numpy`` (streaming compressed-domain merges, timed
per query) and ``jax`` (batched in-graph execution — all of a column's
queries share padded device dispatches).  Backend row-id agreement is
validated per configuration.

The cascaded scenario measures the compressed execution path
(``execute_compressed`` + LRU sub-plan cache): a shared ``In`` selector
AND'd with a rotating ``Eq`` filter — the dashboard-cascade workload —
reporting cache hit rate and cached / cold compressed vs dense-jax
``us_per_query``.

The segmented scenario measures the append/seal/compact lifecycle
(``repro.core.lifecycle``): segment-count vs ``size_words`` vs
``us_per_query`` across monolithic / multi-segment / compacted layouts,
plus the cache-invalidation contract — after an append (new segment) or a
compaction, only touched segments' cached results miss; the steady-state
and post-mutation hit rates are reported and validated.

The LSM scenario measures the delete/TTL/compaction surface on the same
lifecycle: query latency and per-segment plan merges before any delete,
after tombstoning (the live mask must add exactly one merge per touched
segment — the acceptance bound), and after a purging compaction (zero
extra merges: tombstones are physically gone), with every phase validated
against a dense alive-mask oracle.

The range-sweep scenario measures the pluggable encoding layer
(``repro.core.encodings``): ``Range`` cost across range width x column
cardinality x encoding (equality k-of-N vs bit-sliced planes vs
histogram-equalized bins), on both backends, with per-plan merge counts —
the equality encoding's OR fan-in grows with width while bit-sliced stays
at <= 2 * ceil(log2 card) merges; every cell validates bit-identical rows
against the equality encoding.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import And, BitmapIndex, Eq, In, IndexSpec, IndexWriter, Or
from repro.core.query import (NumpyBackend, compile_plan, count_merges,
                              get_backend, lower_plan)
from repro.data.tables import make_census_like


REPS = 3           # min-of-N trials: single samples are too noisy to gate on
MIN_WINDOW = 0.05  # grow each timed window to >= 50ms so scheduler jitter
                   # and timer resolution stop dominating the cheap rows


def _best_of(fn, reps=REPS):
    """Robust timing for the CI trend gate: estimate once, scale the inner
    loop so a trial spans >= MIN_WINDOW seconds, take the min of ``reps``
    trials.  Returns (result, best seconds per single fn() call)."""
    t0 = time.perf_counter()
    out = fn()
    est = time.perf_counter() - t0
    inner = max(1, int(MIN_WINDOW / max(est, 1e-9)))
    best = est
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn()
        best = min(best, (time.perf_counter() - t0) / inner)
    return out, best


def run(n=60_000, queries=40, quick=False):
    if quick:
        n, queries = 20_000, 10
    cols = make_census_like(n)
    rng = np.random.default_rng(0)
    out = []
    for k in (1, 2):
        for sort in ("unsorted", "lex"):
            idx = BitmapIndex.build(
                cols, IndexSpec(k=k, row_order=sort, column_order="given"))
            for ci in range(len(cols)):
                card = int(cols[idx.original_column(ci)].max()) + 1
                vals = rng.integers(0, card, size=queries)
                preds = [Eq(idx.original_column(ci), int(v)) for v in vals]

                np_results, best = _best_of(
                    lambda: [idx.query(p, backend="numpy") for p in preds])
                dt_np = best / queries
                scanned = sum(sc for _, sc in np_results)
                out.append({"k": k, "sort": sort, "column": ci,
                            "backend": "numpy", "cardinality": card,
                            "us_per_query": dt_np * 1e6,
                            "words_scanned": scanned / queries})

                # untimed warmup so jit trace/compile stays out of the
                # timed region (the numpy path has no comparable cost)
                idx.query_many(preds, backend="jax")
                jax_results, best = _best_of(
                    lambda: idx.query_many(preds, backend="jax"))
                dt_jax = best / queries
                agrees = all(
                    np.array_equal(rn, rj)
                    for (rn, _), (rj, _) in zip(np_results, jax_results))
                out.append({"k": k, "sort": sort, "column": ci,
                            "backend": "jax", "cardinality": card,
                            "us_per_query": dt_jax * 1e6,
                            "words_scanned":
                                sum(sc for _, sc in jax_results) / queries,
                            "agrees_with_numpy": agrees})
    out.extend(run_cascaded(cols, queries=queries))
    out.extend(run_segmented(cols, queries=queries))
    out.extend(run_lsm(cols, queries=queries))
    out.extend(run_range_sweep(n=n // 3, queries=queries))
    out.extend(run_adaptive(n=n // 3, queries=queries))
    out.extend(run_fusion(n=n // 2, queries=queries))
    out.extend(run_distributed(cols, queries=queries))
    return out


def run_distributed(cols, queries=24, hosts=(2, 4)):
    """Multi-host serve-plane scenario (docs/dist.md): the same segmented
    index served in-process vs through a :class:`ServePlane` fleet of 2
    and 4 segment-owning worker processes.  Rows report steady-state
    ``us_per_query`` (rotating predicate batches so the content-digest
    result cache can't short-circuit execution on either surface),
    aggregate speedup vs the single-process engine, and the
    compressed-shipped vs dense-shipped byte ratio — the wire-efficiency
    claim (results cross as EWAH streams, never densified).  Bit-identity
    against the local engine validates on every fleet size; the
    near-linear-throughput gate is core-count-aware (a 1-core runner
    cannot parallelize 4 worker processes, so it reports instead of
    failing)."""
    import os

    from repro.dist.serve_plane import ServePlane

    spec = IndexSpec(k=1, row_order="lex", column_order="given")
    n = len(cols[0])
    cards = [int(c.max()) + 1 for c in cols]
    rng = np.random.default_rng(3)
    pool = [
        [And(In(2, range(1 + int(w))), Eq(0, int(v)))
         for v in rng.integers(0, cards[0], size=queries)]
        for w in rng.integers(1, cards[2], size=16)
    ]

    def fill(writer):
        chunk = -(-n // 8)
        for i in range(0, n, chunk):
            writer.append([c[i : i + chunk] for c in cols])
            writer.seal()
        writer.close()

    w = IndexWriter(spec)
    fill(w)
    view = w.index
    expected = [view.query_many(b, backend="numpy") for b in pool]

    def timed(surface):
        calls = iter(range(1 << 30))

        def go():
            return surface.query_many(pool[next(calls) % len(pool)],
                                      backend="numpy")

        _, best = _best_of(go)
        return best / queries

    cpus = float(os.cpu_count() or 1)
    us_one = timed(view) * 1e6
    out = [{"scenario": "distributed", "hosts": 1, "backend": "numpy",
            "us_per_query": us_one, "cpus": cpus, "speedup_vs_one": 1.0,
            "agrees_with_local": True}]
    for nh in hosts:
        wp = IndexWriter(spec)
        fill(wp)
        plane = ServePlane(wp, n_hosts=nh)
        try:
            got = [plane.query_many(b, backend="numpy") for b in pool]
            agrees = all(
                np.array_equal(r, e)
                for gb, eb in zip(got, expected)
                for (r, _), (e, _) in zip(gb, eb))
            us = timed(plane) * 1e6
            s = plane.stats()
            out.append({
                "scenario": "distributed", "hosts": nh, "backend": "numpy",
                "us_per_query": us, "cpus": cpus,
                "speedup_vs_one": us_one / max(us, 1e-9),
                "compressed_to_dense":
                    s["result_bytes_compressed"]
                    / max(s["result_bytes_dense"], 1),
                "ship_bytes": float(s["ship_bytes"]),
                "agrees_with_local": agrees})
        finally:
            plane.close()
    return out


def run_adaptive(n=20_000, queries=24):
    """Adaptive-encoding scenario (the workload loop, docs/containers.md):
    a static ``auto`` index vs a workload-recompacted one over the SAME
    skewed card~300 column, under a point-lookup mix and a wide-range mix.

    The adaptive writer carries ``workload_stats``: the mix's queries run
    against it (recording real ``(shape, width, merges, us)`` samples
    through the production telemetry path), then one compaction consults
    the fitted cost model and re-encodes the merged segment.  The point
    mix should flip the column to ``roaring`` (Eq = one container fold,
    zero stream merges — vs the static chooser's bit-sliced pick at
    card >= 256, which pays ~2*ceil(log2 card) merges per Eq); the
    wide-range mix should keep a range-friendly encoding.  The acceptance
    gate: adaptive beats static on at least one mix, in ``us_per_query``
    or in ``size_words``."""
    from repro.core import Range, evaluate_mask
    from repro.workload import WORKLOAD_STATS

    rng = np.random.default_rng(23)
    card = 300
    # skewed toward low values (the histogram-aware sweet spot): a few hot
    # values dominate, the tail is sparse
    col = np.minimum((rng.random(n) ** 2.5 * card).astype(np.int64),
                     card - 1)
    card = int(col.max()) + 1
    spec = IndexSpec(k=1, row_order="lex", column_order="given",
                     encoding="auto")
    width = max(2, int(card * 0.85))
    mixes = {
        "point": [Eq(0, int(v)) for v in rng.integers(0, card,
                                                      size=queries)],
        "range": [Range(0, int(lo), int(lo) + width - 1)
                  for lo in rng.integers(0, card - width + 1,
                                         size=queries)],
    }
    out = []
    for mix, preds in mixes.items():
        static = BitmapIndex.build([col], spec)
        w = IndexWriter(spec, workload_stats=WORKLOAD_STATS)
        half = len(col) // 2
        w.append([col[:half]])
        w.seal()
        w.append([col[half:]])
        w.seal()
        view = w.index
        # drive the mix through the real telemetry path until the model
        # has enough samples (make_compaction_chooser needs >= 32 even at
        # --quick query counts), then let compaction consult it
        WORKLOAD_STATS.clear()
        while len(WORKLOAD_STATS) < max(2 * queries, 40):
            view.query_many(preds, backend="numpy")
        merged = w.compact(span=(0, 2))
        chosen = merged.index.encodings()[0]
        view = w.index  # segment tuples are copy-on-write: re-snapshot

        expect = [np.flatnonzero(evaluate_mask(p, [col])) for p in preds]

        def run_static():
            return [np.sort(static.row_perm[r])
                    for r, _ in static.query_many(preds, backend="numpy")]

        got_s, best_s = _best_of(run_static)
        got_a, best_a = _best_of(
            lambda: view.query_many(preds, backend="numpy"))
        out.append({"scenario": "adaptive", "mix": mix, "index": "static",
                    "encoding": static.encodings()[0],
                    "us_per_query": best_s / queries * 1e6,
                    "size_words": static.size_words(),
                    "agrees_with_dense_oracle": all(
                        np.array_equal(a, b)
                        for a, b in zip(got_s, expect))})
        out.append({"scenario": "adaptive", "mix": mix, "index": "adaptive",
                    "encoding": chosen,
                    "us_per_query": best_a / queries * 1e6,
                    "size_words": w.size_words(),
                    "agrees_with_dense_oracle": all(
                        np.array_equal(a, b)
                        for (a, _), b in zip(got_a, expect))})
        WORKLOAD_STATS.clear()  # the timed runs re-recorded samples
    return out


def run_fusion(n=30_000, queries=40):
    """Plan-fusion scenario: whole compiled plans in ONE launch (the
    instruction-tape megakernel, ``repro.kernels.planfuse``) vs the
    per-stage jax path, across plan shapes (1/3/4 merge stages — a
    *stage* is an interior op node, one kernel dispatch on the per-stage
    path) and two capacity buckets (two index sizes).

    Timings per cell:

    * ``us_per_query`` — end-to-end ``execute_compressed_many`` on the
      real backend paths (``get_backend("jax")`` fused vs ``fuse=False``
      per-stage), result cache cleared every trial so the engine always
      executes.  Informational + trend-gated; off TPU both paths run the
      Pallas *interpreter*, whose per-op constant is a correctness
      vehicle, not a perf signal.
    * ``fused_eval_us`` vs ``stage_eval_us`` — the plan evaluation alone
      (decompressed planes already on device) through the machine's
      COMPILED executors.  Fused: one program (megakernel on TPU, the
      XLA-fused tape program elsewhere — intermediates never leave
      chip).  Per-stage: one separately-compiled kernel call per
      interior node, every stage's intermediate materialized — exactly
      the dispatch + HBM bounce a Pallas call per stage costs on TPU.
      The fused-beats-per-stage acceptance check runs on this surface
      (>= 3 stages), and the within-2x-of-roofline check compares
      ``fused_eval_us`` against ``roofline.query_bound_us``.
    * ``fused_kernel_us`` — the actual Pallas launch (interpret mode off
      TPU), informational.

    Every fused stream must be bit-identical (canonical EWAH words) to
    both the per-stage jax result and the numpy oracle.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import ewah, ewah_jax
    from repro.core.query import PLAN_STATS
    from repro.kernels import ops as kops
    from .roofline import query_bound_us, stream_bandwidth

    rng = np.random.default_rng(17)
    spec = IndexSpec(k=1, row_order="lex", column_order="given")
    fused = get_backend("jax")
    per_stage = get_backend("jax", fuse=False)
    oracle = NumpyBackend()
    on_tpu = jax.default_backend() == "tpu"
    bw = stream_bandwidth()

    def count_stages(node):
        kind = node[0]
        if kind == "leaf":
            return 0
        if kind == "not":
            return count_stages(node[1])
        children = node[2] if kind == "fold" else node[1]
        return 1 + sum(count_stages(c) for c in children)

    out = []
    for bucket, rows_n in (("small", n // 4), ("large", n)):
        cols = make_census_like(rows_n)
        idx = BitmapIndex.build(cols, spec)
        cards = [int(c.max()) + 1 for c in cols]
        cell_preds = (
            # nested trees: per-stage dispatches one kernel per interior
            # node, so these are 1 / 3 / 4 launches vs fused's one
            (1, lambda v: And(Eq(0, v % cards[0]), Eq(1, v % cards[1]))),
            (3, lambda v: Or(And(Eq(0, v % cards[0]), Eq(1, v % cards[1])),
                             And(Eq(2, v % cards[2]),
                                 Eq(3, v % cards[3])))),
            (4, lambda v: Or(And(Eq(0, v % cards[0]),
                                 In(1, (v % cards[1],
                                        (v + 1) % cards[1]))),
                             And(Eq(2, v % cards[2]),
                                 Eq(3, v % cards[3])))),
        )
        for stages, make_pred in cell_preds:
            preds = [make_pred(int(v))
                     for v in rng.integers(0, 100_000, size=queries)]
            plans = [compile_plan(idx, p) for p in preds]
            merges = count_merges(plans[0].root)
            assert count_stages(plans[0].root) == stages, plans[0].root
            n_words = plans[0].n_words
            cap = PLAN_STATS.capacity_for(
                max(len(s) for s in plans[0].streams))

            def timed_engine(be):
                be.execute_compressed_many(plans)   # jit warmup untimed

                def cold():
                    be.result_cache.clear()         # engine must execute
                    return be.execute_compressed_many(plans)

                streams, best = _best_of(cold)
                return streams, best / queries * 1e6

            fused_streams, us_fused = timed_engine(fused)
            stage_streams, us_stage = timed_engine(per_stage)
            ref = oracle.execute_compressed_many(plans)
            agrees_f = all(np.array_equal(a.data, b.data)
                           for a, b in zip(fused_streams, ref))
            agrees_s = all(np.array_equal(a.data, b.data)
                           for a, b in zip(stage_streams, ref))
            agrees_fs = all(np.array_equal(a.data, b.data)
                            for a, b in zip(fused_streams, stage_streams))

            # fused evaluation alone over on-device planes: the roofline
            # comparison surface (see docstring for the executor choice)
            tape, _ = lower_plan(plans[0].root)
            m = sum(1 for opcode, _ in tape if opcode == 0)
            planes = np.stack([
                np.concatenate([
                    ewah.decompress(np.asarray(p.streams[j], np.uint32),
                                    n_words)
                    for p in plans])
                for j in range(m)])
            # tile the batch up to a floor byte volume so the timing
            # measures bandwidth, not the fixed dispatch overhead (at
            # quick sizes a whole batch is a few hundred KB and the
            # ~30us jit-call cost would swamp the data movement)
            reps = max(1, -(-8 * 2**20 // planes.nbytes))
            eval_queries = queries * reps
            x = jax.numpy.asarray(np.tile(planes, (1, reps)))

            def eval_with(use_kernel):
                def go():
                    r, _k = kops.plan_fuse(x, tape, use_kernel=use_kernel)
                    jax.block_until_ready(r)
                    return r

                go()                                # compile untimed
                _, best = _best_of(go)
                return best / eval_queries * 1e6

            # per-stage evaluation surface: one separately-compiled
            # kernel call per interior node (kops.* are individually
            # jitted), every stage's intermediate materialized — the
            # dispatch + memory bounce fusion removes
            def stage_node(node):
                kind = node[0]
                if kind == "leaf":
                    return x[node[1]]
                if kind == "not":
                    return stage_node(node[1]) ^ jnp.uint32(0xFFFFFFFF)
                if kind == "fold":
                    parts = jnp.stack([stage_node(c) for c in node[2]])
                    return kops.slice_fold(parts, node[1],
                                           use_kernel=on_tpu)
                parts = jnp.stack([stage_node(c) for c in node[1]])
                return kops.wordops_fold(parts, kind, use_kernel=on_tpu)

            classify = jax.jit(ewah_jax.classify)

            def stage_go():
                r = stage_node(plans[0].root)
                k = classify(r)                     # fused does this in-kernel
                jax.block_until_ready((r, k))
                return r

            stage_go()                              # compile untimed
            _, best = _best_of(stage_go)
            stage_eval_us = best / eval_queries * 1e6

            fused_eval_us = eval_with(on_tpu)
            fused_kernel_us = eval_with(True)
            roofline_us = query_bound_us(m * n_words, n_words, bw=bw)

            out.append({"scenario": "fusion", "bucket": bucket,
                        "stages": stages, "merges": merges,
                        "backend": "jax-fused",
                        "capacity": float(cap),
                        "us_per_query": us_fused,
                        "fused_eval_us": fused_eval_us,
                        "stage_eval_us": stage_eval_us,
                        "fused_kernel_us": fused_kernel_us,
                        "roofline_us": roofline_us,
                        "roofline_ratio": fused_eval_us / roofline_us,
                        "agrees_with_numpy": agrees_f,
                        "agrees_with_per_stage": agrees_fs})
            out.append({"scenario": "fusion", "bucket": bucket,
                        "stages": stages, "merges": merges,
                        "backend": "jax-per-stage",
                        "capacity": float(cap),
                        "us_per_query": us_stage,
                        "agrees_with_numpy": agrees_s})
    return out


def run_range_sweep(n=20_000, queries=24):
    """Encoding scenario: Range cost vs (width x cardinality x encoding),
    both backends.  Every (encoding, backend) cell must return row ids
    bit-identical to the equality encoding."""
    from repro.core import Range

    rng = np.random.default_rng(11)
    out = []
    for card in (64, 256, 1024):
        col = rng.integers(0, card, size=n)
        indexes = {
            enc: BitmapIndex.build([col], IndexSpec(
                k=1, row_order="lex", column_order="given", encoding=enc))
            for enc in ("equality", "bitsliced", "binned")
        }
        for wname, frac in (("narrow", 0.1), ("wide", 0.5)):
            width = max(1, int(card * frac))
            los = rng.integers(0, card - width + 1, size=queries)
            preds = [Range(0, int(lo), int(lo) + width - 1) for lo in los]
            reference = None  # equality runs first: the agreement oracle
            for enc, idx in indexes.items():
                merges = float(np.mean([count_merges(
                    compile_plan(idx, p).root) for p in preds]))
                np_results, best = _best_of(
                    lambda: idx.query_many(preds, backend="numpy"))
                rows = [np.sort(idx.row_perm[r]) for r, _ in np_results]
                if reference is None:
                    reference = rows
                agrees = all(np.array_equal(a, b)
                             for a, b in zip(reference, rows))
                out.append({"scenario": "range-sweep", "cardinality": card,
                            "encoding": enc, "width": wname,
                            "backend": "numpy", "merges": merges,
                            "us_per_query": best / queries * 1e6,
                            "agrees_with_equality": agrees})
                idx.query_many(preds, backend="jax")   # jit warmup untimed
                jax_results, best = _best_of(
                    lambda: idx.query_many(preds, backend="jax"))
                rows_j = [np.sort(idx.row_perm[r]) for r, _ in jax_results]
                agrees = all(np.array_equal(a, b)
                             for a, b in zip(reference, rows_j))
                out.append({"scenario": "range-sweep", "cardinality": card,
                            "encoding": enc, "width": wname,
                            "backend": "jax", "merges": merges,
                            "us_per_query": best / queries * 1e6,
                            "agrees_with_equality": agrees})
    return out


def run_cascaded(cols, queries=40):
    """Cascaded-query scenario: shared sub-plans through the compressed
    engine's result cache, against the cold compressed path and the dense
    (row-id) jax path."""
    idx = BitmapIndex.build(
        cols, IndexSpec(k=1, row_order="lex", column_order="given"))
    card0 = int(cols[0].max()) + 1
    card2 = int(cols[2].max()) + 1
    shared = In(2, range(card2 // 2))          # the dashboard's selector
    preds = [And(shared, Eq(0, v % card0)) for v in range(queries)]
    plans = [compile_plan(idx, p) for p in preds]

    cached = NumpyBackend()                    # fresh caches, not the shared
    cold = NumpyBackend()                      # get_backend() instances
    # first pass is the cold-start cascade (its hit rate is the reported
    # number); timing is min-of-N over the warm steady state
    cached_results = [cached.execute_compressed(p) for p in plans]
    hit_rate = cached.result_cache.hit_rate
    _, best = _best_of(
        lambda: [cached.execute_compressed(p) for p in plans])
    dt_cached = best / queries

    def run_cold():
        for p in plans:
            cold.execute_compressed(p)
            cold.result_cache.clear()

    _, best = _best_of(run_cold)
    dt_cold = best / queries

    jx = get_backend("jax")
    jx.execute_many(plans)                     # warmup: compile out of timing
    jax_results, best = _best_of(lambda: jx.execute_many(plans))
    dt_dense = best / queries
    agrees = all(np.array_equal(s.to_rows(), rows)
                 for s, (rows, _) in zip(cached_results, jax_results))

    out = [{"scenario": "cascaded", "backend": "numpy-compressed-cached",
            "us_per_query": dt_cached * 1e6,
            "cache_hit_rate": hit_rate,
            "agrees_with_dense": agrees},
           {"scenario": "cascaded", "backend": "numpy-compressed-cold",
            "us_per_query": dt_cold * 1e6, "cache_hit_rate": 0.0},
           {"scenario": "cascaded", "backend": "jax-dense",
            "us_per_query": dt_dense * 1e6}]
    return out


def run_segmented(cols, queries=40):
    """Append/seal/compact lifecycle scenario: layout cost (segment count vs
    compressed size vs query latency over the SAME rows) and
    segment-generation cache invalidation (appends/compactions evict only
    touched entries)."""
    spec = IndexSpec(k=1, row_order="lex", column_order="given")
    n = len(cols[0])
    cards = [int(c.max()) + 1 for c in cols]
    preds = [And(In(2, range(cards[2] // 2)), Eq(0, v % cards[0]))
             for v in range(queries)]
    out = []

    # -- layout cost: monolithic vs 4-way segmented vs compacted, all over
    # exactly the same n rows (the writer is closed, so sealed == n)
    mono = BitmapIndex.build(cols, spec)
    w = IndexWriter(spec)
    for i in range(0, n, -(-n // 4)):
        w.append([c[i : i + -(-n // 4)] for c in cols])
        w.seal()
    w.close()
    be = get_backend("numpy", cache_size=4096)

    def timed_layout(layout, run_queries, n_segments, size_words):
        def cold():
            be.result_cache.clear()          # cold compressed path per trial
            return run_queries()

        _, best = _best_of(cold)
        out.append({"scenario": "segmented", "layout": layout,
                    "segments": n_segments, "size_words": size_words,
                    "us_per_query": best / queries * 1e6})

    def run_mono():
        # same execution surface as the segmented layouts (compile + the
        # compressed engine + row materialization), so the timing isolates
        # the LAYOUT, not the row-id-vs-compressed path difference
        streams = be.execute_compressed_many(
            [compile_plan(mono, p) for p in preds])
        return [np.sort(mono.row_perm[s.to_rows()]) for s in streams]

    timed_layout("monolithic", run_mono, 1, mono.size_words())
    view = w.index
    timed_layout("4-segment",
                 lambda: view.query_many(preds, backend="numpy",
                                         cache_size=4096),
                 len(w.segments), w.size_words())
    w.compact(span=(0, len(w.segments)))
    timed_layout("compacted",
                 lambda: view.query_many(preds, backend="numpy",
                                         cache_size=4096),
                 len(w.segments), w.size_words())
    out.append({"scenario": "segmented", "layout": "size-check",
                "segments": len(w.segments),
                "size_words": w.size_words(),
                "monolithic_words": mono.size_words(),
                "agrees_with_monolithic": all(
                    np.array_equal(
                        rows_seg, np.sort(mono.row_perm[mono.query(p)[0]]))
                    for p, (rows_seg, _) in zip(
                        preds[:5],
                        view.query_many(preds[:5], backend="numpy")))})

    # -- cache invalidation: a live (open) writer; steady-state hit rate,
    # then an append (new segment: old entries keep hitting) and a
    # compaction (exactly the retired segments' entries evicted)
    w2 = IndexWriter(spec)
    for i in range(0, n, -(-n // 4)):
        w2.append([c[i : i + -(-n // 4)] for c in cols])
        w2.seal()
    view2 = w2.index
    be.result_cache.clear()

    def hit_rate_of_pass():
        be.result_cache.hits = be.result_cache.misses = 0
        view2.query_many(preds, backend="numpy", cache_size=4096)
        return be.result_cache.hit_rate

    view2.query_many(preds, backend="numpy", cache_size=4096)  # populate
    steady = hit_rate_of_pass()

    r = np.random.default_rng(7)
    w2.append([r.integers(0, c, size=n // 5) for c in cards])
    w2.seal()
    post_append = hit_rate_of_pass()

    entries_before = len(be.result_cache)
    w2.compact(span=(len(w2.segments) - 2, len(w2.segments)))
    evicted = entries_before - len(be.result_cache)
    post_compact = hit_rate_of_pass()

    for phase, rate, extra in (
            ("steady", steady, {}),
            ("post-append", post_append, {}),
            ("post-compact", post_compact,
             {"entries_evicted": evicted, "entries_before": entries_before})):
        out.append({"scenario": "segmented-cache", "phase": phase,
                    "cache_hit_rate": rate, **extra})
    return out


def run_lsm(cols, queries=40):
    """Delete/TTL/compaction scenario: the cost of a delete is one
    compressed-domain merge per segment at query time (the cached live
    mask ANDed into the plan root), and a purging compaction removes even
    that.  Phases: pre-delete, post-delete (tombstones live), post-compact
    (tombstoned rows physically purged, aligned so no fillers remain)."""
    from repro.core.query import with_live_mask
    from repro.core import evaluate_mask

    spec = IndexSpec(k=1, row_order="lex", column_order="given")
    n = len(cols[0])
    cards = [int(c.max()) + 1 for c in cols]
    preds = [And(In(2, range(cards[2] // 2)), Eq(0, v % cards[0]))
             for v in range(queries)]
    w = IndexWriter(spec)
    for i in range(0, n, -(-n // 4)):
        w.append([c[i : i + -(-n // 4)] for c in cols])
        w.seal()
    w.close()
    view = w.index
    alive = np.ones(n, dtype=bool)
    out = []

    def extra_merges():
        """Max over segments of (merges with live mask - base merges)."""
        worst = 0
        for seg in w.segments:
            if not seg.n_rows:
                continue
            base = count_merges(compile_plan(seg.index, preds[0]).root)
            wrapped = with_live_mask(compile_plan(seg.index, preds[0]),
                                     seg.live_stream())
            worst = max(worst, count_merges(wrapped.root) - base)
        return worst

    def phase(name):
        want = [np.flatnonzero(evaluate_mask(p, cols) & alive)
                for p in preds]
        got, best = _best_of(
            lambda: view.query_many(preds, backend="numpy"))
        agrees = all(np.array_equal(r, e) for (r, _), e in zip(got, want))
        out.append({"scenario": "lsm", "phase": name,
                    "us_per_query": best / queries * 1e6,
                    "extra_merges_per_segment": extra_merges(),
                    "segments": len(w.segments),
                    "size_words": w.size_words(),
                    "live_rows": int(alive.sum()),
                    "agrees_with_oracle": agrees})

    phase("pre-delete")
    # tombstone a word-aligned slab from every segment (aligned so the
    # final compaction purges cleanly, no fillers left behind)
    dead = np.concatenate([np.arange(s.row_start, s.row_start + 64)
                           for s in w.segments])
    w.delete(row_ids=dead)
    alive[dead] = False
    phase("post-delete")
    w.compact(span=(0, len(w.segments)))
    phase("post-compact")
    return out


def validate(rows):
    checks = []

    # sorting reduces words scanned on the primary column (numpy backend
    # words-scanned is the streaming-cursor cost, the paper's proxy)
    def get(k, sort, ci):
        return [r for r in rows if r.get("k") == k and r.get("sort") == sort
                and r.get("column") == ci and r["backend"] == "numpy"][0]
    for k in (1, 2):
        s, u = get(k, "lex", 0), get(k, "unsorted", 0)
        ok = s["words_scanned"] <= u["words_scanned"]
        checks.append(f"k={k}: sort cuts primary-column scan "
                      f"({s['words_scanned']:.0f} vs {u['words_scanned']:.0f}): "
                      f"{'PASS' if ok else 'FAIL'}")
    # k=2 queries scan more than k=1 (paper: larger k slows queries)
    s1, s2 = get(1, "lex", 3), get(2, "lex", 3)
    ok = s2["words_scanned"] >= s1["words_scanned"]
    checks.append(f"k=2 scans >= k=1 on large column "
                  f"({s2['words_scanned']:.0f} vs {s1['words_scanned']:.0f}): "
                  f"{'PASS' if ok else 'FAIL'}")
    # numpy and jax backends return identical row ids everywhere
    jax_rows = [r for r in rows
                if r.get("backend") == "jax" and "agrees_with_numpy" in r]
    ok = bool(jax_rows) and all(r["agrees_with_numpy"] for r in jax_rows)
    checks.append(f"jax backend row ids match numpy on "
                  f"{len(jax_rows)} configs: {'PASS' if ok else 'FAIL'}")
    # cascaded scenario: the shared sub-plan cache actually hits, and the
    # compressed cached path agrees with the dense backend
    casc = {r["backend"]: r for r in rows if r.get("scenario") == "cascaded"}
    hit = casc["numpy-compressed-cached"]["cache_hit_rate"]
    ok = hit > 0.0
    checks.append(f"cascade sub-plan cache hit rate {hit:.0%}: "
                  f"{'PASS' if ok else 'FAIL'}")
    ok = casc["numpy-compressed-cached"]["agrees_with_dense"]
    checks.append(f"cascade compressed rows match dense backend: "
                  f"{'PASS' if ok else 'FAIL'}")
    cached = casc["numpy-compressed-cached"]["us_per_query"]
    cold = casc["numpy-compressed-cold"]["us_per_query"]
    dense = casc["jax-dense"]["us_per_query"]
    checks.append(f"cascade us/query cached {cached:.0f} vs cold {cold:.0f} "
                  f"vs dense-jax {dense:.0f}: "
                  f"{'PASS' if cached <= cold else 'FAIL'}")
    # segmented lifecycle: compaction recovers the monolithic size (within
    # 10%), answers stay bit-identical, and segment-generation invalidation
    # evicts only touched entries (hit rate stays > 0 after mutations)
    seg = {r["layout"]: r for r in rows if r.get("scenario") == "segmented"}
    sc = seg["size-check"]
    ratio = sc["size_words"] / max(sc["monolithic_words"], 1)
    checks.append(
        f"segmented: compacted size {sc['size_words']} within 10% of "
        f"monolithic {sc['monolithic_words']} (ratio {ratio:.2f}): "
        f"{'PASS' if ratio <= 1.10 else 'FAIL'}")
    checks.append(f"segmented rows match monolithic rebuild: "
                  f"{'PASS' if sc['agrees_with_monolithic'] else 'FAIL'}")
    ok = seg["4-segment"]["size_words"] >= seg["compacted"]["size_words"]
    checks.append(
        f"segmented: compaction shrinks index "
        f"({seg['4-segment']['size_words']} -> "
        f"{seg['compacted']['size_words']} words): "
        f"{'PASS' if ok else 'FAIL'}")
    cache = {r["phase"]: r for r in rows
             if r.get("scenario") == "segmented-cache"}
    steady = cache["steady"]["cache_hit_rate"]
    checks.append(f"segmented cache steady-state hit rate {steady:.0%}: "
                  f"{'PASS' if steady > 0.9 else 'FAIL'}")
    pa = cache["post-append"]["cache_hit_rate"]
    checks.append(
        f"append evicts nothing (untouched segments keep hitting): "
        f"post-append hit rate {pa:.0%}: {'PASS' if pa > 0.5 else 'FAIL'}")
    pc = cache["post-compact"]
    ok = 0 < pc["entries_evicted"] < pc["entries_before"] \
        and pc["cache_hit_rate"] > 0
    checks.append(
        f"compaction evicts only touched entries "
        f"({pc['entries_evicted']}/{pc['entries_before']}, post-compact "
        f"hit rate {pc['cache_hit_rate']:.0%}): {'PASS' if ok else 'FAIL'}")
    # LSM scenario: a delete costs at most ONE extra merge per segment at
    # query time, a purging compaction costs ZERO, and every phase answers
    # like the dense alive-mask oracle
    lsm = {r["phase"]: r for r in rows if r.get("scenario") == "lsm"}
    ok = all(r["agrees_with_oracle"] for r in lsm.values())
    checks.append(f"lsm: all phases match dense alive-mask oracle: "
                  f"{'PASS' if ok else 'FAIL'}")
    pre, post, comp = (lsm["pre-delete"], lsm["post-delete"],
                       lsm["post-compact"])
    checks.append(
        f"lsm: delete adds <= 1 merge/segment "
        f"({pre['extra_merges_per_segment']} -> "
        f"{post['extra_merges_per_segment']}): "
        f"{'PASS' if pre['extra_merges_per_segment'] == 0 and post['extra_merges_per_segment'] <= 1 else 'FAIL'}")
    checks.append(
        f"lsm: compaction purges the merge back to zero "
        f"({comp['extra_merges_per_segment']} extra, "
        f"{comp['live_rows']} live rows): "
        f"{'PASS' if comp['extra_merges_per_segment'] == 0 else 'FAIL'}")
    ok = comp["live_rows"] == post["live_rows"] < pre["live_rows"]
    checks.append(
        f"lsm: live rows {pre['live_rows']} -> {post['live_rows']} stable "
        f"through compaction: {'PASS' if ok else 'FAIL'}")
    # range-sweep: every encoding/backend cell answers bit-identically to
    # the equality encoding
    sweep = [r for r in rows if r.get("scenario") == "range-sweep"]
    ok = bool(sweep) and all(r["agrees_with_equality"] for r in sweep)
    checks.append(f"range-sweep: rows bit-identical to equality encoding "
                  f"across {len(sweep)} cells: {'PASS' if ok else 'FAIL'}")

    def sweep_cell(card, enc, width, backend="numpy"):
        return [r for r in sweep if r["cardinality"] == card
                and r["encoding"] == enc and r["width"] == width
                and r["backend"] == backend][0]

    # bit-sliced ranges stay within the 2*ceil(log2 card) merge budget
    bs = sweep_cell(1024, "bitsliced", "wide")
    checks.append(
        f"range-sweep: card-1024 bit-sliced wide range merges "
        f"{bs['merges']:.0f} <= 20 (vs "
        f"{sweep_cell(1024, 'equality', 'wide')['merges']:.0f} equality): "
        f"{'PASS' if bs['merges'] <= 20 else 'FAIL'}")
    # acceptance: bit-sliced beats equality on wide ranges at card >= 256
    for card in (256, 1024):
        e = sweep_cell(card, "equality", "wide")["us_per_query"]
        b = sweep_cell(card, "bitsliced", "wide")["us_per_query"]
        checks.append(
            f"range-sweep: card-{card} wide-range bit-sliced "
            f"{b:.0f}us < equality {e:.0f}us: "
            f"{'PASS' if b < e else 'FAIL'}")
    # adaptive scenario: the workload-recompacted index answers the dense
    # oracle exactly, picks different encodings for point vs range mixes,
    # and beats the static auto chooser on at least one mix (time or size)
    adap = [r for r in rows if r.get("scenario") == "adaptive"]
    ok = bool(adap) and all(r["agrees_with_dense_oracle"] for r in adap)
    checks.append(f"adaptive: rows match the dense oracle across "
                  f"{len(adap)} cells: {'PASS' if ok else 'FAIL'}")

    def adaptive_cell(mix, index):
        return [r for r in adap if r["mix"] == mix
                and r["index"] == index][0]

    enc_pt = adaptive_cell("point", "adaptive")["encoding"]
    enc_rg = adaptive_cell("range", "adaptive")["encoding"]
    checks.append(
        f"adaptive: chosen encoding tracks the mix "
        f"(point={enc_pt}, range={enc_rg}): "
        f"{'PASS' if enc_pt != enc_rg else 'FAIL'}")
    wins = []
    for mix in ("point", "range"):
        s, a = adaptive_cell(mix, "static"), adaptive_cell(mix, "adaptive")
        if (a["us_per_query"] < s["us_per_query"]
                or a["size_words"] < s["size_words"]):
            wins.append(f"{mix} ({a['encoding']} "
                        f"{a['us_per_query']:.0f}us/{a['size_words']}w vs "
                        f"{s['encoding']} "
                        f"{s['us_per_query']:.0f}us/{s['size_words']}w)")
    checks.append(
        f"adaptive: workload-recompacted index beats static auto on >= 1 "
        f"mix [{'; '.join(wins) or 'none'}]: "
        f"{'PASS' if wins else 'FAIL'}")
    # fusion scenario: megakernel streams bit-identical everywhere, the
    # fused (one-launch) evaluation beats the per-stage (one compiled
    # kernel per interior node, materialized intermediates) evaluation on
    # deep (>= 3 stage) plans, and stays within 2x of the memory-bandwidth
    # roofline bound
    fus = [r for r in rows if r.get("scenario") == "fusion"]
    ok = bool(fus) and all(r["agrees_with_numpy"] for r in fus) \
        and all(r.get("agrees_with_per_stage", True) for r in fus)
    checks.append(f"fusion: streams bit-identical (numpy oracle + "
                  f"per-stage) across {len(fus)} cells: "
                  f"{'PASS' if ok else 'FAIL'}")
    for f in (r for r in fus if r["backend"] == "jax-fused"):
        if f["stages"] >= 3:
            ok = f["fused_eval_us"] < f["stage_eval_us"]
            checks.append(
                f"fusion: {f['bucket']}/{f['stages']}-stage fused eval "
                f"{f['fused_eval_us']:.2f}us < per-stage eval "
                f"{f['stage_eval_us']:.2f}us: {'PASS' if ok else 'FAIL'}")
        ok = f["roofline_ratio"] <= 2.0
        checks.append(
            f"fusion: {f['bucket']}/{f['stages']}-stage fused eval "
            f"{f['fused_eval_us']:.2f}us within 2x of roofline "
            f"{f['roofline_us']:.2f}us (ratio {f['roofline_ratio']:.2f}): "
            f"{'PASS' if ok else 'FAIL'}")
    # distributed scenario: every fleet size answers bit-identically to
    # the in-process engine, shipped results stay compressed (< 0.2 of
    # dense 1-bit-per-row shipping), and 4 worker processes reach >= 3x
    # aggregate throughput — the last gate only where the runner actually
    # has >= 4 cores to parallelize onto
    dist = [r for r in rows if r.get("scenario") == "distributed"]
    if dist:
        ok = all(r["agrees_with_local"] for r in dist)
        checks.append(
            f"distributed: plane rows bit-identical to local engine "
            f"across {len(dist)} fleet sizes: {'PASS' if ok else 'FAIL'}")
        for r in dist:
            if r["hosts"] < 2:
                continue
            ratio = r["compressed_to_dense"]
            checks.append(
                f"distributed: {r['hosts']}-host compressed-shipped / "
                f"dense bytes {ratio:.3f} < 0.2: "
                f"{'PASS' if ratio < 0.2 else 'FAIL'}")
        four = [r for r in dist if r["hosts"] == 4]
        if four:
            r = four[0]
            if r["cpus"] >= 4:
                ok = r["speedup_vs_one"] >= 3.0
                checks.append(
                    f"distributed: 4-host aggregate throughput "
                    f"{r['speedup_vs_one']:.2f}x >= 3x single-process: "
                    f"{'PASS' if ok else 'FAIL'}")
            else:
                checks.append(
                    f"distributed: 4-host throughput gate skipped on a "
                    f"{r['cpus']:.0f}-core runner (measured "
                    f"{r['speedup_vs_one']:.2f}x): PASS")
    return checks


def main():
    """``python -m benchmarks.bench_fig6 --fusion-smoke``: the CI smoke
    for the fused path — tiny inputs, gates only on the noise-immune
    checks (bit-identical streams everywhere, fused eval within 2x of
    the roofline bound); the fused-vs-per-stage eval race gates in
    ``benchmarks.run``'s validate at full bench sizes."""
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--fusion-smoke", action="store_true",
                    help="run only the plan-fusion scenario at smoke size")
    args = ap.parse_args()
    if not args.fusion_smoke:
        ap.error("only --fusion-smoke is supported as a direct entrypoint")
    rows = run_fusion(n=12_000, queries=8)
    failed = False
    for r in (r for r in rows if r["backend"] == "jax-fused"):
        ok = (r["agrees_with_numpy"] and r["agrees_with_per_stage"]
              and r["roofline_ratio"] <= 2.0)
        failed |= not ok
        print(f"fusion-smoke {r['bucket']}/{r['stages']}-stage: "
              f"bit-identical={r['agrees_with_numpy'] and r['agrees_with_per_stage']} "
              f"roofline-ratio={r['roofline_ratio']:.2f} "
              f"fused-eval={r['fused_eval_us']:.2f}us "
              f"stage-eval={r['stage_eval_us']:.2f}us: "
              f"{'PASS' if ok else 'FAIL'}")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
