"""Figs. 6-7: equality-query cost per column — wall-clock of our codec AND
the machine-independent proxy (compressed words scanned), sorted vs
unsorted, k = 1, 2.  The paper's (2 - 1/k) * n_i^((k-1)/k) model is checked
on the words-scanned proxy."""

from __future__ import annotations

import time

import numpy as np

from repro.core.bitmap_index import BitmapIndex
from repro.data.tables import make_census_like


def run(n=60_000, queries=40, quick=False):
    if quick:
        n, queries = 20_000, 10
    cols = make_census_like(n)
    rng = np.random.default_rng(0)
    out = []
    for k in (1, 2):
        for sort in ("unsorted", "lex"):
            idx = BitmapIndex.build(cols, k=k, row_order=sort,
                                    column_order=None, materialize=True)
            for ci in range(len(cols)):
                card = int(cols[idx.original_column(ci)].max()) + 1
                vals = rng.integers(0, card, size=queries)
                t0 = time.perf_counter()
                scanned = 0
                for v in vals:
                    _, sc = idx.equality_query(ci, int(v))
                    scanned += sc
                dt = (time.perf_counter() - t0) / queries
                out.append({"k": k, "sort": sort, "column": ci,
                            "cardinality": card,
                            "us_per_query": dt * 1e6,
                            "words_scanned": scanned / queries})
    return out


def validate(rows):
    checks = []
    # sorting reduces words scanned on the primary column
    def get(k, sort, ci):
        return [r for r in rows if r["k"] == k and r["sort"] == sort
                and r["column"] == ci][0]
    for k in (1, 2):
        s, u = get(k, "lex", 0), get(k, "unsorted", 0)
        ok = s["words_scanned"] <= u["words_scanned"]
        checks.append(f"k={k}: sort cuts primary-column scan "
                      f"({s['words_scanned']:.0f} vs {u['words_scanned']:.0f}): "
                      f"{'PASS' if ok else 'FAIL'}")
    # k=2 queries scan more than k=1 (paper: larger k slows queries)
    s1, s2 = get(1, "lex", 3), get(2, "lex", 3)
    ok = s2["words_scanned"] >= s1["words_scanned"]
    checks.append(f"k=2 scans >= k=1 on large column "
                  f"({s2['words_scanned']:.0f} vs {s1['words_scanned']:.0f}): "
                  f"{'PASS' if ok else 'FAIL'}")
    return checks
