"""Figs. 6-7: equality-query cost per column — wall-clock of our codec AND
the machine-independent proxy (compressed words scanned), sorted vs
unsorted, k = 1, 2.  The paper's (2 - 1/k) * n_i^((k-1)/k) model is checked
on the words-scanned proxy.

Queries run through the predicate planner (repro.core.query) on both
execution backends: ``numpy`` (streaming compressed-domain merges, timed
per query) and ``jax`` (batched in-graph execution — all of a column's
queries share padded device dispatches).  Backend row-id agreement is
validated per configuration.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import BitmapIndex, Eq, IndexSpec
from repro.data.tables import make_census_like


def run(n=60_000, queries=40, quick=False):
    if quick:
        n, queries = 20_000, 10
    cols = make_census_like(n)
    rng = np.random.default_rng(0)
    out = []
    for k in (1, 2):
        for sort in ("unsorted", "lex"):
            idx = BitmapIndex.build(
                cols, IndexSpec(k=k, row_order=sort, column_order="given"))
            for ci in range(len(cols)):
                card = int(cols[idx.original_column(ci)].max()) + 1
                vals = rng.integers(0, card, size=queries)
                preds = [Eq(idx.original_column(ci), int(v)) for v in vals]

                t0 = time.perf_counter()
                np_results = [idx.query(p, backend="numpy") for p in preds]
                dt_np = (time.perf_counter() - t0) / queries
                scanned = sum(sc for _, sc in np_results)
                out.append({"k": k, "sort": sort, "column": ci,
                            "backend": "numpy", "cardinality": card,
                            "us_per_query": dt_np * 1e6,
                            "words_scanned": scanned / queries})

                # untimed warmup so jit trace/compile stays out of the
                # timed region (the numpy path has no comparable cost)
                idx.query_many(preds, backend="jax")
                t0 = time.perf_counter()
                jax_results = idx.query_many(preds, backend="jax")
                dt_jax = (time.perf_counter() - t0) / queries
                agrees = all(
                    np.array_equal(rn, rj)
                    for (rn, _), (rj, _) in zip(np_results, jax_results))
                out.append({"k": k, "sort": sort, "column": ci,
                            "backend": "jax", "cardinality": card,
                            "us_per_query": dt_jax * 1e6,
                            "words_scanned":
                                sum(sc for _, sc in jax_results) / queries,
                            "agrees_with_numpy": agrees})
    return out


def validate(rows):
    checks = []

    # sorting reduces words scanned on the primary column (numpy backend
    # words-scanned is the streaming-cursor cost, the paper's proxy)
    def get(k, sort, ci):
        return [r for r in rows if r["k"] == k and r["sort"] == sort
                and r["column"] == ci and r["backend"] == "numpy"][0]
    for k in (1, 2):
        s, u = get(k, "lex", 0), get(k, "unsorted", 0)
        ok = s["words_scanned"] <= u["words_scanned"]
        checks.append(f"k={k}: sort cuts primary-column scan "
                      f"({s['words_scanned']:.0f} vs {u['words_scanned']:.0f}): "
                      f"{'PASS' if ok else 'FAIL'}")
    # k=2 queries scan more than k=1 (paper: larger k slows queries)
    s1, s2 = get(1, "lex", 3), get(2, "lex", 3)
    ok = s2["words_scanned"] >= s1["words_scanned"]
    checks.append(f"k=2 scans >= k=1 on large column "
                  f"({s2['words_scanned']:.0f} vs {s1['words_scanned']:.0f}): "
                  f"{'PASS' if ok else 'FAIL'}")
    # numpy and jax backends return identical row ids everywhere
    jax_rows = [r for r in rows if r["backend"] == "jax"]
    ok = bool(jax_rows) and all(r["agrees_with_numpy"] for r in jax_rows)
    checks.append(f"jax backend row ids match numpy on "
                  f"{len(jax_rows)} configs: {'PASS' if ok else 'FAIL'}")
    return checks
