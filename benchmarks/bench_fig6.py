"""Figs. 6-7: equality-query cost per column — wall-clock of our codec AND
the machine-independent proxy (compressed words scanned), sorted vs
unsorted, k = 1, 2.  The paper's (2 - 1/k) * n_i^((k-1)/k) model is checked
on the words-scanned proxy.

Queries run through the predicate planner (repro.core.query) on both
execution backends: ``numpy`` (streaming compressed-domain merges, timed
per query) and ``jax`` (batched in-graph execution — all of a column's
queries share padded device dispatches).  Backend row-id agreement is
validated per configuration.

The cascaded scenario measures the compressed execution path
(``execute_compressed`` + LRU sub-plan cache): a shared ``In`` selector
AND'd with a rotating ``Eq`` filter — the dashboard-cascade workload —
reporting cache hit rate and cached / cold compressed vs dense-jax
``us_per_query``.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import And, BitmapIndex, Eq, In, IndexSpec
from repro.core.query import NumpyBackend, compile_plan, get_backend
from repro.data.tables import make_census_like


REPS = 3           # min-of-N trials: single samples are too noisy to gate on
MIN_WINDOW = 0.05  # grow each timed window to >= 50ms so scheduler jitter
                   # and timer resolution stop dominating the cheap rows


def _best_of(fn, reps=REPS):
    """Robust timing for the CI trend gate: estimate once, scale the inner
    loop so a trial spans >= MIN_WINDOW seconds, take the min of ``reps``
    trials.  Returns (result, best seconds per single fn() call)."""
    t0 = time.perf_counter()
    out = fn()
    est = time.perf_counter() - t0
    inner = max(1, int(MIN_WINDOW / max(est, 1e-9)))
    best = est
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn()
        best = min(best, (time.perf_counter() - t0) / inner)
    return out, best


def run(n=60_000, queries=40, quick=False):
    if quick:
        n, queries = 20_000, 10
    cols = make_census_like(n)
    rng = np.random.default_rng(0)
    out = []
    for k in (1, 2):
        for sort in ("unsorted", "lex"):
            idx = BitmapIndex.build(
                cols, IndexSpec(k=k, row_order=sort, column_order="given"))
            for ci in range(len(cols)):
                card = int(cols[idx.original_column(ci)].max()) + 1
                vals = rng.integers(0, card, size=queries)
                preds = [Eq(idx.original_column(ci), int(v)) for v in vals]

                np_results, best = _best_of(
                    lambda: [idx.query(p, backend="numpy") for p in preds])
                dt_np = best / queries
                scanned = sum(sc for _, sc in np_results)
                out.append({"k": k, "sort": sort, "column": ci,
                            "backend": "numpy", "cardinality": card,
                            "us_per_query": dt_np * 1e6,
                            "words_scanned": scanned / queries})

                # untimed warmup so jit trace/compile stays out of the
                # timed region (the numpy path has no comparable cost)
                idx.query_many(preds, backend="jax")
                jax_results, best = _best_of(
                    lambda: idx.query_many(preds, backend="jax"))
                dt_jax = best / queries
                agrees = all(
                    np.array_equal(rn, rj)
                    for (rn, _), (rj, _) in zip(np_results, jax_results))
                out.append({"k": k, "sort": sort, "column": ci,
                            "backend": "jax", "cardinality": card,
                            "us_per_query": dt_jax * 1e6,
                            "words_scanned":
                                sum(sc for _, sc in jax_results) / queries,
                            "agrees_with_numpy": agrees})
    out.extend(run_cascaded(cols, queries=queries))
    return out


def run_cascaded(cols, queries=40):
    """Cascaded-query scenario: shared sub-plans through the compressed
    engine's result cache, against the cold compressed path and the dense
    (row-id) jax path."""
    idx = BitmapIndex.build(
        cols, IndexSpec(k=1, row_order="lex", column_order="given"))
    card0 = int(cols[0].max()) + 1
    card2 = int(cols[2].max()) + 1
    shared = In(2, range(card2 // 2))          # the dashboard's selector
    preds = [And(shared, Eq(0, v % card0)) for v in range(queries)]
    plans = [compile_plan(idx, p) for p in preds]

    cached = NumpyBackend()                    # fresh caches, not the shared
    cold = NumpyBackend()                      # get_backend() instances
    # first pass is the cold-start cascade (its hit rate is the reported
    # number); timing is min-of-N over the warm steady state
    cached_results = [cached.execute_compressed(p) for p in plans]
    hit_rate = cached.result_cache.hit_rate
    _, best = _best_of(
        lambda: [cached.execute_compressed(p) for p in plans])
    dt_cached = best / queries

    def run_cold():
        for p in plans:
            cold.execute_compressed(p)
            cold.result_cache.clear()

    _, best = _best_of(run_cold)
    dt_cold = best / queries

    jx = get_backend("jax")
    jx.execute_many(plans)                     # warmup: compile out of timing
    jax_results, best = _best_of(lambda: jx.execute_many(plans))
    dt_dense = best / queries
    agrees = all(np.array_equal(s.to_rows(), rows)
                 for s, (rows, _) in zip(cached_results, jax_results))

    out = [{"scenario": "cascaded", "backend": "numpy-compressed-cached",
            "us_per_query": dt_cached * 1e6,
            "cache_hit_rate": hit_rate,
            "agrees_with_dense": agrees},
           {"scenario": "cascaded", "backend": "numpy-compressed-cold",
            "us_per_query": dt_cold * 1e6, "cache_hit_rate": 0.0},
           {"scenario": "cascaded", "backend": "jax-dense",
            "us_per_query": dt_dense * 1e6}]
    return out


def validate(rows):
    checks = []

    # sorting reduces words scanned on the primary column (numpy backend
    # words-scanned is the streaming-cursor cost, the paper's proxy)
    def get(k, sort, ci):
        return [r for r in rows if r.get("k") == k and r.get("sort") == sort
                and r.get("column") == ci and r["backend"] == "numpy"][0]
    for k in (1, 2):
        s, u = get(k, "lex", 0), get(k, "unsorted", 0)
        ok = s["words_scanned"] <= u["words_scanned"]
        checks.append(f"k={k}: sort cuts primary-column scan "
                      f"({s['words_scanned']:.0f} vs {u['words_scanned']:.0f}): "
                      f"{'PASS' if ok else 'FAIL'}")
    # k=2 queries scan more than k=1 (paper: larger k slows queries)
    s1, s2 = get(1, "lex", 3), get(2, "lex", 3)
    ok = s2["words_scanned"] >= s1["words_scanned"]
    checks.append(f"k=2 scans >= k=1 on large column "
                  f"({s2['words_scanned']:.0f} vs {s1['words_scanned']:.0f}): "
                  f"{'PASS' if ok else 'FAIL'}")
    # numpy and jax backends return identical row ids everywhere
    jax_rows = [r for r in rows if r["backend"] == "jax"]
    ok = bool(jax_rows) and all(r["agrees_with_numpy"] for r in jax_rows)
    checks.append(f"jax backend row ids match numpy on "
                  f"{len(jax_rows)} configs: {'PASS' if ok else 'FAIL'}")
    # cascaded scenario: the shared sub-plan cache actually hits, and the
    # compressed cached path agrees with the dense backend
    casc = {r["backend"]: r for r in rows if r.get("scenario") == "cascaded"}
    hit = casc["numpy-compressed-cached"]["cache_hit_rate"]
    ok = hit > 0.0
    checks.append(f"cascade sub-plan cache hit rate {hit:.0%}: "
                  f"{'PASS' if ok else 'FAIL'}")
    ok = casc["numpy-compressed-cached"]["agrees_with_dense"]
    checks.append(f"cascade compressed rows match dense backend: "
                  f"{'PASS' if ok else 'FAIL'}")
    cached = casc["numpy-compressed-cached"]["us_per_query"]
    cold = casc["numpy-compressed-cold"]["us_per_query"]
    dense = casc["jax-dense"]["us_per_query"]
    checks.append(f"cascade us/query cached {cached:.0f} vs cold {cold:.0f} "
                  f"vs dense-jax {dense:.0f}: "
                  f"{'PASS' if cached <= cold else 'FAIL'}")
    return checks
