"""Roofline report: three terms per (arch x shape) from the dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.roofline --dryrun results/dryrun \
      --out results/roofline.md

Reads the per-cell JSON written by repro.launch.dryrun (memory analysis,
HLO collective bytes) and combines it with the analytic FLOP/byte models
(benchmarks/analytic.py) — see EXPERIMENTS.md §Roofline for why analytic
FLOPs are authoritative (XLA cost analysis counts scan bodies once).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.shapes import SHAPES, cell_config
from repro.models import transformer

from . import analytic

SDS = jax.ShapeDtypeStruct

# ---------------------------------------------------------------------------
# Query-plane memory-bandwidth bound (the bench_fig6 fusion scenario and
# benchmarks/trend.py's wall-clock-vs-roofline column)
# ---------------------------------------------------------------------------

_MEASURED_BW = None


def stream_bandwidth() -> float:
    """Achievable streaming memory bandwidth (bytes/s) on the machine the
    benchmarks actually run on: ``analytic.HBM_BW`` on TPU, otherwise
    measured once by streaming large uint32 arrays through a bitwise op —
    the same instruction mix the word-space kernels execute, so the bound
    is what THIS machine could do with zero non-memory overhead.
    Memoized; the probe costs ~100 ms."""
    global _MEASURED_BW
    if jax.default_backend() == "tpu":
        return analytic.HBM_BW
    if _MEASURED_BW is None:
        import time

        a = np.arange(8 * 2**20, dtype=np.uint32)   # 32 MiB each side
        b = a[::-1].copy()
        best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            c = np.bitwise_and(a, b)
            best = min(best, time.perf_counter() - t0)
        _MEASURED_BW = (a.nbytes + b.nbytes + c.nbytes) / best
    return _MEASURED_BW


def query_bound_us(leaf_words: float, result_words: float = 0.0,
                   bw: float | None = None) -> float:
    """Memory-bandwidth lower bound (us) for evaluating one fused plan
    over decompressed word planes: every leaf plane word is read once
    (``leaf_words`` = m * W for an m-leaf plan) and the result plus its
    EWAH classification written once (``2 * result_words``) — no
    execution strategy beats moving those bytes.  The fusion acceptance
    gate compares the megakernel's warm wall-clock against this."""
    if bw is None:
        bw = stream_bandwidth()
    return 4.0 * (leaf_words + 2.0 * result_words) / bw * 1e6


def param_count(cfg) -> int:
    shapes = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg), SDS((2,), "uint32"))
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)))


def cell_report(rec: dict) -> dict | None:
    if rec["status"] != "ok":
        return None
    cfg = cell_config(get_config(rec["arch"]), SHAPES[rec["shape"]])
    shape = SHAPES[rec["shape"]]
    n = param_count(cfg)
    n_act = analytic.active_params(cfg, n)
    kind = rec["kind"]
    chips = rec["n_devices"]
    terms = analytic.roofline_terms(
        cfg, shape.global_batch, shape.seq_len, kind, n,
        rec["collectives"]["bytes"], n_chips=chips,
        remat_policy=rec.get("remat_policy", "dots"),
        microbatches=rec.get("microbatches", 1))
    dominant = max(terms, key=terms.get)
    mf = analytic.model_flops(cfg, shape.global_batch, shape.seq_len, kind,
                              n, n_act)
    sf = analytic.step_flops(cfg, shape.global_batch, shape.seq_len, kind,
                             rec.get("remat_policy", "dots"))
    bound_s = max(terms.values())
    mfu_bound = mf / (chips * analytic.PEAK_FLOPS) / bound_s if bound_s else 0
    return {
        **{k: rec[k] for k in ("arch", "shape", "kind", "mesh")},
        "params": n, "active_params": n_act,
        "terms": terms, "dominant": dominant.replace("_s", ""),
        "model_flops": mf, "step_flops": sf,
        "useful_ratio": mf / sf if sf else 0.0,
        "hlo_flops_raw": rec["cost"].get("flops"),
        "roofline_fraction": mfu_bound,
        "temp_bytes_per_dev": rec["memory"]["temp_bytes"],
        "coll_bytes": rec["collectives"]["total_bytes"],
    }


def fmt_row(r):
    t = r["terms"]
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} | "
            f"{t['collective_s']*1e3:.2f} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2%} | "
            f"{r['temp_bytes_per_dev']/2**30:.1f} |")


HEADER = (
    "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
    "| bottleneck | useful flops ratio | roofline frac | temp GiB/dev |\n"
    "|---|---|---|---|---|---|---|---|---|---|")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline.md")
    ap.add_argument("--json-out", default="results/roofline.json")
    args = ap.parse_args()

    with open(os.path.join(args.dryrun, "summary.json")) as f:
        records = json.load(f)
    reports = []
    skipped = []
    for rec in records:
        if rec["status"] == "skipped":
            skipped.append(rec)
            continue
        r = cell_report(rec)
        if r:
            reports.append(r)

    lines = ["# Roofline (single-pod 16x16 = 256 chips unless noted)", "",
             HEADER]
    for r in sorted(reports, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        if r["mesh"] == "16x16":
            lines.append(fmt_row(r))
    lines += ["", "## Multi-pod (2x16x16 = 512 chips)", "", HEADER]
    for r in sorted(reports, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "16x16":
            lines.append(fmt_row(r))
    lines += ["", "## Skipped cells", ""]
    for s in skipped:
        lines.append(f"- {s['mesh']} {s['arch']} {s['shape']}: {s['reason']}")
    out = "\n".join(lines)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write(out + "\n")
    with open(args.json_out, "w") as f:
        json.dump(reports, f, indent=1)
    print(out)


if __name__ == "__main__":
    main()
