"""Fig. 4: Gray-Lex index sizes for all 4! dimension orderings —
uniform cardinalities (200,400,600,800) and Zipfian skews (1.6,1.2,0.8,0.4)
on 100,000 rows; plus the §4.3 heuristic's recommendation quality."""

from __future__ import annotations

import itertools

import numpy as np

from repro.core import IndexSpec
from repro.core.bitmap_index import index_size_report
from repro.core.column_order import order_columns
from repro.data.tables import make_uniform_table, make_zipf_table


def all_orderings_size(cols, k):
    out = {}
    for perm in itertools.permutations(range(len(cols))):
        rep = index_size_report(cols, IndexSpec(
            k=k, row_order="lex", column_order=perm))
        out["".join(str(p + 1) for p in perm)] = rep["total_words"]
    return out


def run(n=100_000, quick=False):
    if quick:
        n = 20_000
    uni = make_uniform_table(n, [200, 400, 600, 800], seed=0)
    zipf = make_zipf_table(n, [100] * 4, [1.6, 1.2, 0.8, 0.4], seed=1)
    results = []
    for name, cols, cards in (
        ("uniform", uni, [200, 400, 600, 800]),
        ("zipf", zipf, [100] * 4),
    ):
        for k in (1, 2) if quick else (1, 2, 3, 4):
            sizes = all_orderings_size(cols, k)
            best = min(sizes, key=sizes.get)
            worst = max(sizes, key=sizes.get)
            heur = order_columns(cards, k)
            heur_name = "".join(str(int(p) + 1) for p in heur)
            results.append({
                "dataset": name, "k": k, "best": best, "worst": worst,
                "best_words": sizes[best], "worst_words": sizes[worst],
                "heuristic": heur_name, "heuristic_words": sizes[heur_name],
                "spread": sizes[worst] / sizes[best],
            })
    return results


def validate(rows):
    """Paper: ordering matters (significant spread); the heuristic is
    near-optimal for k=1 on uniform data."""
    checks = []
    for r in rows:
        if r["dataset"] == "uniform" and r["k"] == 1:
            near = r["heuristic_words"] <= 1.15 * r["best_words"]
            checks.append(
                f"uniform k=1 heuristic {r['heuristic']} within 15% of best "
                f"{r['best']}: {'PASS' if near else 'FAIL'}")
    spread = max(r["spread"] for r in rows)
    checks.append(f"column order changes size (max spread {spread:.2f}x): "
                  f"{'PASS' if spread > 1.2 else 'FAIL'}")
    return checks
