"""Fig. 3: expected storage gain of sorting one column,
2*delta(kn, ceil(k*n_i^(1/k)), n) - 4*n_i, for n = 100,000."""

from __future__ import annotations

import numpy as np

from repro.core.column_order import column_gain


def run(n=100_000, quick=False):
    rows = []
    for k in (1, 2, 3, 4):
        cards = np.unique(np.logspace(1, 5.3, 60).astype(int))
        gains = [column_gain(n, int(c), k) for c in cards]
        best = int(cards[int(np.argmax(gains))])
        rows.append({"k": k, "argmax_card": best,
                     "max_gain": float(max(gains)),
                     "gain_at_10": float(column_gain(n, 10, k)),
                     "gain_at_100k": float(column_gain(n, 100_000, k))})
    return rows


def validate(rows):
    """Paper: gain is modal (rises then falls); maximum near
    (n(w-1)/2)^(k/(k+1)) — the paper cites ~1,200 for k=1 and ~13,400 for
    k=2 at n=100,000 (the closed form is an approximation; we check its
    location only where the paper does, k <= 2)."""
    checks = []
    n, w = 100_000, 32
    for r in rows:
        k = r["k"]
        modal = (r["max_gain"] > r["gain_at_10"]
                 and r["max_gain"] > r["gain_at_100k"])
        checks.append(f"k={k}: gain is modal: {'PASS' if modal else 'FAIL'}")
        if k <= 2:
            pred = (n * (w - 1) / 2) ** (k / (k + 1))
            ok = 0.3 * pred < r["argmax_card"] < 3 * pred
            checks.append(
                f"k={k}: argmax {r['argmax_card']} ~ predicted {pred:.0f}: "
                f"{'PASS' if ok else 'FAIL'}")
    k1 = [r for r in rows if r["k"] == 1][0]
    checks.append(f"k=1 max near 1200 (paper): got {k1['argmax_card']}: "
                  f"{'PASS' if 600 < k1['argmax_card'] < 2400 else 'FAIL'}")
    return checks
