"""Paper §6.4 scaling claim: with sorting, index size grows SUBLINEARLY in
the number of rows ('as new data arrives, it is increasingly likely to fit
into existing runs'); unsorted growth is linear."""

from __future__ import annotations

from repro.core import IndexSpec
from repro.core.bitmap_index import index_size_report
from repro.data.tables import make_kjv4grams_like


def run(quick=False):
    n_max = 400_000 if quick else 2_000_000
    cols_full = make_kjv4grams_like(n_max)
    fractions = [0.25, 0.5, 1.0]
    rows = []
    for f in fractions:
        n = int(n_max * f)
        cols = [c[:n] for c in cols_full]
        srt = index_size_report(cols, IndexSpec(k=1, row_order="lex"))
        uns = index_size_report(cols, IndexSpec(k=1, row_order="unsorted"))
        rows.append({"rows": n, "sorted_words": srt["total_words"],
                     "unsorted_words": uns["total_words"]})
    return rows


def validate(rows):
    checks = []
    r0, r1 = rows[0], rows[-1]
    scale = r1["rows"] / r0["rows"]
    sorted_growth = r1["sorted_words"] / r0["sorted_words"]
    unsorted_growth = r1["unsorted_words"] / r0["unsorted_words"]
    checks.append(
        f"sorted grows sublinearly ({sorted_growth:.2f}x for {scale:.0f}x rows): "
        f"{'PASS' if sorted_growth < 0.8 * scale else 'FAIL'}")
    checks.append(
        f"unsorted grows ~linearly ({unsorted_growth:.2f}x): "
        f"{'PASS' if unsorted_growth > 0.7 * scale else 'FAIL'}")
    return checks
