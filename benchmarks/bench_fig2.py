"""Fig. 2: probability a bitmap holds a dirty word when j of 1000 values
land in one 32-row chunk — GC-adjacent vs lex-adjacent vs random codes."""

from __future__ import annotations

import numpy as np

from repro.core.encoding import choose_N, codes_to_bits, gray_kofn_codes, lex_kofn_codes


def dirty_probability(k: int, j: int, n_values: int = 1000, trials: int = 200,
                      scheme: str = "gray", seed: int = 0) -> float:
    """Monte-Carlo over chunks: j distinct values fill 32 rows; a touched
    bitmap is 'dirty' if its word is neither all-0 nor all-1."""
    N = choose_N(n_values, k)
    enum = lex_kofn_codes if scheme == "lex" else gray_kofn_codes
    codes = codes_to_bits(enum(N, k, n_values), N)
    rng = np.random.default_rng(seed)
    tot_dirty = 0
    for _ in range(trials):
        if scheme == "random":
            cb = codes[rng.choice(n_values, size=j, replace=False)]
        else:
            start = rng.integers(0, n_values - j + 1)
            cb = codes[start : start + j]  # adjacent codes
        # rows: values in sorted runs filling 32 rows
        counts = rng.multinomial(32 - j, np.ones(j) / j) + 1
        rows = np.repeat(np.arange(j), counts)
        word_bits = cb[rows]  # (32, N) bits of this chunk
        col_sum = word_bits.sum(0)
        dirty = (col_sum > 0) & (col_sum < 32)
        tot_dirty += dirty.sum()
    return tot_dirty / (trials * N)


def run(quick=False):
    rows = []
    js = [2, 4, 8, 16, 32] if quick else [2, 4, 6, 8, 12, 16, 24, 32]
    trials = 50 if quick else 200
    for k in (2, 3):
        for scheme in ("gray", "lex", "random"):
            for j in js:
                p = dirty_probability(k, j, trials=trials, scheme=scheme)
                rows.append({"k": k, "scheme": scheme, "j": j, "p_dirty": p})
    return rows


def validate(rows) -> list[str]:
    """Paper: GC ~ lex for k=2; GC substantially better for k>2;
    random disastrous."""
    checks = []
    by = {(r["k"], r["scheme"], r["j"]): r["p_dirty"] for r in rows}
    js = sorted({r["j"] for r in rows})
    mid = js[len(js) // 2]
    ok = by[(3, "gray", mid)] <= by[(3, "lex", mid)] * 1.05
    checks.append(f"k=3 GC <= lex at j={mid}: {'PASS' if ok else 'FAIL'}")
    ok = by[(2, "random", mid)] > by[(2, "gray", mid)]
    checks.append(f"k=2 random worse than GC at j={mid}: {'PASS' if ok else 'FAIL'}")
    ok = all(by[(3, "random", j)] >= by[(3, "gray", j)] for j in js)
    checks.append(f"k=3 random >= GC for all j: {'PASS' if ok else 'FAIL'}")
    return checks
